"""Two-stage late-interaction retrieval pipeline (paper App. A.1).

Stage 1: per-token kNN candidate generation (+ Eq. 15 bounds).
Stage 2: exact or pruned reranking over the candidate MaxSim matrix, with
         method ∈ {exact, bandit (Alg. 1), batched (TPU variant),
         uniform (Alg. 2), topmargin (Alg. 3)}.

Cost accounting follows the paper: the atomic unit is one MaxSim cell
(Sec. 2.1); FLOPs additionally weight each cell by its true document length
(2 * M * L_i per cell), so "coverage" and "MaxSim FLOPs saved" are both
reported.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BanditConfig
from repro.core import metrics as M
from repro.core.bandit import run_bandit
from repro.core.batched import run_batched_oracle
from repro.core.baselines import doc_top_margin, doc_uniform, exact_topk
from repro.data.synthetic import RetrievalDataset
from repro.kernels import ref as kref
from repro.kernels.ops import maxsim_op
from repro.retrieval.ann import CandidateSet, generate_candidates, generic_bounds
from repro.retrieval.index import TokenIndex, build_index
from repro.retrieval.service import rerank_bandit_step, rerank_dense_step


@dataclasses.dataclass
class RerankResult:
    topk_docs: np.ndarray        # (K,) global doc ids
    coverage: float              # Eq. 6
    flops: float                 # MaxSim FLOPs actually spent
    flops_exact: float           # FLOPs of full reranking
    overlap: float               # Eq. 16 vs exact rerank
    metrics: Dict[str, float]    # recall/mrr/ndcg vs qrels (if given)
    rounds: int = 0
    separated: bool = True


def _cell_flops(doc_lens: jax.Array, revealed: jax.Array, dim: int) -> jax.Array:
    """FLOPs = sum over revealed cells of 2*M*L_i."""
    per_doc = revealed.sum(axis=-1).astype(jnp.float32)       # cells per doc
    return jnp.sum(per_doc * doc_lens.astype(jnp.float32)) * 2.0 * dim


def rerank_query(
    index: TokenIndex,
    query: jax.Array,                 # (T, M)
    *,
    method: str = "bandit",
    k: int = 5,
    bandit: Optional[BanditConfig] = None,
    use_ann_bounds: bool = True,
    prereveal_ann: bool = False,      # beyond-paper: seed with stage-1 cells
    budget_fraction: float = 0.25,    # for the static baselines
    kprime: int = 10,
    max_candidates: int = 256,
    use_kernel: bool = False,
    qrels_row: Optional[np.ndarray] = None,
    seed: int = 0,
) -> RerankResult:
    bandit = bandit or BanditConfig(k=k)
    T = query.shape[0]
    cand = generate_candidates(index.doc_embs, index.doc_mask, query,
                               kprime=kprime, max_candidates=max_candidates,
                               support=bandit.support)
    embs, tok_mask = index.gather_docs(cand.doc_ids)
    if use_kernel:
        h_full = maxsim_op(embs, tok_mask, query)
    else:
        h_full = kref.maxsim_ref(embs, tok_mask, query)
    h_full = jnp.where(cand.doc_mask[:, None], h_full, 0.0)

    if use_ann_bounds:
        a, b = cand.a, cand.b
    else:
        a, b = generic_bounds(*h_full.shape, support=bandit.support)
        a = jnp.where(cand.doc_mask[:, None], a, 0.0)
        b = jnp.where(cand.doc_mask[:, None], b, 0.0)

    exact_idx, _ = exact_topk(h_full, k=k, doc_mask=cand.doc_mask)
    doc_lens = jnp.take(index.doc_lens, jnp.maximum(cand.doc_ids, 0))
    doc_lens = jnp.where(cand.doc_mask, doc_lens, 0)
    flops_exact = float(_cell_flops(
        doc_lens, jnp.broadcast_to(cand.doc_mask[:, None], h_full.shape),
        index.dim))

    key = jax.random.key(seed)
    rounds, separated = 0, True
    if method == "exact":
        topk_hat = exact_idx
        revealed = jnp.broadcast_to(cand.doc_mask[:, None], h_full.shape)
        coverage = 1.0
    elif method == "bandit":
        # Beyond-paper option: stage-1 already computed some cells exactly —
        # reveal them for free before the LUCB loop starts.
        res = run_bandit(
            h_full, a, b, key, k=k, delta=bandit.delta,
            alpha_ef=bandit.alpha_ef, epsilon=bandit.epsilon,
            radius_c=bandit.radius_c, bias_kappa=bandit.bias_kappa,
            warmup_fraction=bandit.warmup_fraction,
            doc_mask=cand.doc_mask,
            init_one_per_doc=not prereveal_ann,
            prereveal=cand.known_mask if prereveal_ann else None)
        topk_hat, revealed = res.topk, res.revealed
        if prereveal_ann:
            # stage-1 cells cost nothing; subtract them from the bill
            revealed = res.revealed & ~cand.known_mask
        coverage = float(res.coverage)
        rounds, separated = int(res.rounds), bool(res.separated)
    elif method == "batched":
        res = run_batched_oracle(
            h_full, a, b, key, k=k, delta=bandit.delta,
            alpha_ef=bandit.alpha_ef, epsilon=bandit.epsilon,
            radius_c=bandit.radius_c, bias_kappa=bandit.bias_kappa,
            block_docs=bandit.block_docs,
            block_tokens=bandit.block_tokens, doc_mask=cand.doc_mask)
        topk_hat, revealed = res.topk, res.revealed
        coverage = float(res.coverage)
        rounds, separated = int(res.rounds), bool(res.separated)
    elif method == "uniform":
        res = doc_uniform(h_full, key, k=k,
                          budget=max(1, int(budget_fraction * T)),
                          doc_mask=cand.doc_mask)
        topk_hat, revealed, coverage = res.topk, res.revealed, float(res.coverage)
    elif method == "topmargin":
        res = doc_top_margin(h_full, a, b, k=k,
                             budget=max(1, int(budget_fraction * T)),
                             doc_mask=cand.doc_mask)
        topk_hat, revealed, coverage = res.topk, res.revealed, float(res.coverage)
    else:
        raise ValueError(f"unknown method {method!r}")

    flops = float(_cell_flops(doc_lens, revealed, index.dim))
    overlap = float(M.overlap_at_k(topk_hat, exact_idx))

    topk_docs = np.asarray(jnp.take(cand.doc_ids, topk_hat))
    task_metrics: Dict[str, float] = {}
    if qrels_row is not None:
        rel = jnp.asarray(qrels_row)
        rel_cand = jnp.where(cand.doc_mask, rel[jnp.maximum(cand.doc_ids, 0)],
                             False)
        task_metrics = {
            "recall": float(M.recall_at_k(topk_hat, rel_cand)),
            "mrr": float(M.mrr_at_k(topk_hat, rel_cand)),
            "ndcg": float(M.ndcg_at_k(topk_hat, rel_cand)),
        }
    return RerankResult(topk_docs=topk_docs, coverage=coverage, flops=flops,
                        flops_exact=flops_exact, overlap=overlap,
                        metrics=task_metrics, rounds=rounds,
                        separated=separated)


@dataclasses.dataclass
class ServeResult:
    """Batched pipeline output (numpy, ready for the caller)."""

    topk_scores: np.ndarray      # (B, K) f32
    topk_ids: np.ndarray         # (B, K) global doc ids, -1 padded
    reveal_fraction: np.ndarray  # (B,) fraction of MaxSim cells computed
    stats: np.ndarray            # (3,) [occupancy, rounds, lockstep waste]


def serve_queries(
    index,
    queries,                     # (B, T, M)
    *,
    k: int = 5,
    flavor: str = "bandit",      # "dense" | "bandit"
    kprime: int = 10,
    max_candidates: int = 64,
    bandit: Optional[BanditConfig] = None,
    engine: str = "pooled",
    max_rounds: int = -1,
    seed: int = 0,
) -> ServeResult:
    """The unified batched pipeline entrypoint: stage-1 kNN + Eq. 15 bounds
    feeding the SAME engine-facing rerank steps ``RetrievalEngine``
    AOT-compiles (``service.rerank_dense_step`` / ``rerank_bandit_step``) —
    what the examples run is what the engine serves.

    ``index`` is duck-typed: a ``TokenIndex`` (``doc_embs``/``doc_mask``),
    a ``repro.retrieval.corpus.Corpus`` facade, or any object exposing
    ``embs``/``mask``. (:func:`rerank_query` remains the single-query
    research harness with the full method zoo and FLOP accounting.)"""
    embs = getattr(index, "embs", None)
    mask = getattr(index, "mask", None)
    if embs is None:
        embs, mask = index.doc_embs, index.doc_mask
    bandit = bandit or BanditConfig(k=k)
    queries = jnp.asarray(queries, jnp.float32)

    cand = jax.vmap(lambda qq: generate_candidates(
        embs, mask, qq, kprime=kprime, max_candidates=max_candidates,
        support=bandit.support))(queries)
    key = jax.random.key(seed)
    if flavor == "dense":
        scores, gids, frac, stats = rerank_dense_step(
            embs, mask, queries, cand.doc_ids, cand.a, cand.b, key, topk=k)
    elif flavor == "bandit":
        scores, gids, frac, stats = rerank_bandit_step(
            embs, mask, queries, cand.doc_ids, cand.a, cand.b, key, topk=k,
            alpha_ef=bandit.alpha_ef, delta=bandit.delta,
            block_docs=bandit.block_docs, block_tokens=bandit.block_tokens,
            max_rounds=max_rounds, engine=engine)
    else:
        raise ValueError(f"unknown serving flavor {flavor!r}")
    return ServeResult(topk_scores=np.asarray(scores),
                       topk_ids=np.asarray(gids),
                       reveal_fraction=np.asarray(frac),
                       stats=np.asarray(stats))


def evaluate_dataset(
    dataset: RetrievalDataset,
    *,
    method: str = "bandit",
    k: int = 5,
    bandit: Optional[BanditConfig] = None,
    **kw,
) -> Dict[str, float]:
    """Mean coverage / overlap / task metrics over all queries."""
    index = build_index(dataset.doc_embs, dataset.doc_mask, dataset.doc_lens)
    rows = []
    for qi in range(dataset.n_queries):
        r = rerank_query(index, jnp.asarray(dataset.queries[qi]),
                         method=method, k=k, bandit=bandit,
                         qrels_row=dataset.qrels[qi], seed=qi, **kw)
        rows.append(r)
    out = {
        "coverage": float(np.mean([r.coverage for r in rows])),
        "coverage_std": float(np.std([r.coverage for r in rows])),
        "overlap": float(np.mean([r.overlap for r in rows])),
        "flops_saving": float(np.mean(
            [r.flops_exact / max(r.flops, 1.0) for r in rows])),
    }
    if rows and rows[0].metrics:
        for key in rows[0].metrics:
            out[key] = float(np.mean([r.metrics[key] for r in rows]))
    return out
