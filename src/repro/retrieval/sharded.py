"""Mesh-resident corpus for the sharded serving flavors.

The paper's drop-in claim holds only while the (C, L, M) token index fits
on one device; at production scale the index is sharded by construction
(ColBERTv2's residual-compressed shards, our {"data": 16, "model": 16} and
pod meshes). :class:`ShardedCorpus` is the one object that owns that
placement:

  * the doc dim is padded to a multiple of the mesh's shard count and
    placed with ``NamedSharding`` over EVERY mesh axis
    (``repro.dist.sharding.corpus_specs``) — shard ``s`` owns the
    contiguous global rows ``[s*docs_per_shard, (s+1)*docs_per_shard)``,
    so a real doc's padded-global id IS its original id;
  * the ragged tail is explicit metadata, not a convention: ``valid_docs``
    counts the genuine docs per shard (the trailing shards of an odd-size
    corpus own fewer, possibly zero), and the shard_map flavors clamp their
    global-id math against it (`service._shard_global_ids`);
  * :func:`route_candidates` is the host-side stage-1 routing table:
    global candidate ids -> per-shard local slot lists, the layout every
    corpus-resident ``shard_map`` flavor consumes.

Pad rows carry an all-False token mask and zero embeddings, so they can
never contribute score mass even before the id clamp drops them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.dist.sharding import corpus_axes, corpus_specs
from repro.kernels.quant import CORPUS_FORMATS, QuantTokens, quantize


@dataclasses.dataclass(frozen=True)
class ShardedCorpus:
    """A (C, L, M) token index resident on a device mesh.

    ``embs``/``mask`` (and ``pooled`` when present) are device arrays whose
    doc dim is sharded over every mesh axis; ``n_docs`` is the TRUE corpus
    size, ``docs_per_shard * n_shards`` the padded one.

    ``fmt`` is the resident corpus format (``kernels.quant.CORPUS_FORMATS``).
    For ``int8``/``residual``, ``embs`` is a ``QuantTokens`` pytree whose
    payload/sidecar leaves shard exactly like a dense corpus (doc dim over
    every axis; the residual codebook replicates) — the kernels dequantize
    per VMEM block, so shard HBM holds compressed bytes only.
    """

    embs: jax.Array                      # (C_pad, L, M) f32 | bf16 |
                                         #   QuantTokens (int8 + sidecars)
    mask: jax.Array                      # (C_pad, L) bool — pads all-False
    mesh: Mesh
    n_docs: int                          # genuine docs (C)
    n_shards: int
    docs_per_shard: int                  # C_pad // n_shards
    valid_docs: np.ndarray               # (n_shards,) i32 genuine docs/shard
    pooled: Optional[jax.Array] = None   # (C_pad, M) two-phase summaries
    # Centroid-router state for shard-local stage-1 (a
    # ``repro.retrieval.corpus.CentroidRouter``; typed as object to keep
    # this module free of a corpus.py import cycle). Replicated arrays.
    router: Optional[object] = None
    fmt: str = "bf16"                    # resident format (CORPUS_FORMATS)

    @property
    def padded_docs(self) -> int:
        return self.n_shards * self.docs_per_shard

    def valid_docs_device(self) -> jax.Array:
        """(n_shards,) i32, replicated — the clamp table the shard_map
        flavors index by their own axis position."""
        return jnp.asarray(self.valid_docs, jnp.int32)


def corpus_embs_spec(mesh: Mesh, corpus_format: str = "bf16"):
    """The shard_map/``NamedSharding`` spec for a corpus ``embs`` operand.

    Dense formats get the plain ``corpus_specs(mesh)["embs"]`` PartitionSpec;
    ``int8``/``residual`` get a ``QuantTokens`` OF PartitionSpecs whose tree
    structure matches the resident pytree leaf-for-leaf (shard_map in_specs
    must mirror operand structure). Callers building specs before they hold
    the corpus pass the format string instead of inspecting arrays."""
    specs = corpus_specs(mesh)
    if corpus_format == "bf16":
        return specs["embs"]
    if corpus_format not in CORPUS_FORMATS:
        raise ValueError(f"unknown corpus format {corpus_format!r}; "
                         f"expected one of {CORPUS_FORMATS}")
    return QuantTokens(
        data=specs["embs"], scales=specs["scales"],
        codes=specs["codes"] if corpus_format == "residual" else None,
        codebook=specs["codebook"] if corpus_format == "residual" else None)


def shard_corpus(embs, mask, mesh: Mesh, *, pooled=None, router=None,
                 n_centroids: int = 0, router_iters: int = 10,
                 router_seed: int = 0,
                 corpus_format: str = "bf16") -> ShardedCorpus:
    """Pad the doc dim to the mesh's shard count and place every corpus
    array with its ``corpus_specs`` NamedSharding.

    A ``bfloat16`` corpus stays bfloat16 on the mesh (half the per-shard
    HBM; every kernel op accumulates in f32); other dtypes normalize to
    f32.

    ``corpus_format`` selects the resident encoding (``"bf16"`` keeps the
    dense behavior above — source dtype passes through). ``"int8"``
    quantizes each (doc, token) row symmetrically against a resident bf16
    scale; ``"residual"`` additionally stores a centroid id per row and
    int8-quantizes only the residual against the router codebook
    (ColBERTv2-style), so the residual path REQUIRES a router —
    ``n_centroids`` defaults to 8 when neither a router nor a count is
    given. Quantization happens host-side on the padded arrays, so pad
    rows encode with scale 0 and decode to exact zeros (int8) or
    ``centroids[0]`` (residual); either way their all-False token mask
    keeps them out of every max.

    ``n_centroids > 0`` additionally builds the shard-local stage-1
    centroid router (``repro.retrieval.corpus.build_router``) over the
    same contiguous-block placement, at shard time; a prebuilt ``router``
    may be passed instead. Either way its (tiny) arrays are placed
    replicated on the mesh."""
    if corpus_format not in CORPUS_FORMATS:
        raise ValueError(f"unknown corpus format {corpus_format!r}; "
                         f"expected one of {CORPUS_FORMATS}")
    embs = np.asarray(embs)
    if embs.dtype != jnp.bfloat16:
        embs = embs.astype(np.float32)
    mask = np.asarray(mask, bool)
    if embs.ndim != 3 or mask.ndim != 2 or embs.shape[:2] != mask.shape:
        raise ValueError("corpus must be (C, L, M) embs + (C, L) mask")
    C = embs.shape[0]
    n_shards = int(np.prod([mesh.shape[a] for a in corpus_axes(mesh)]))
    c_loc = -(-max(C, 1) // n_shards)            # ceil; >=1 so shapes stay real
    pad = n_shards * c_loc - C
    if pad:
        embs = np.pad(embs, ((0, pad), (0, 0), (0, 0)))
        mask = np.pad(mask, ((0, pad), (0, 0)))  # pads False => masked out
    valid = np.clip(C - c_loc * np.arange(n_shards), 0, c_loc).astype(np.int32)
    specs = corpus_specs(mesh)
    put = lambda x, s: jax.device_put(x, NamedSharding(mesh, s))
    pooled_dev = None
    if pooled is not None:
        pooled = np.asarray(pooled, np.float32)
        if pad:
            pooled = np.pad(pooled, ((0, pad), (0, 0)))
        pooled_dev = put(pooled, specs["pooled"])
    if corpus_format == "residual" and router is None and not n_centroids:
        n_centroids = 8  # the residual codebook IS the router's centroids
    if router is None and n_centroids:
        # late import: corpus.py is the facade ABOVE this module
        from repro.retrieval.corpus import build_router
        router = build_router(embs, mask, n_shards=n_shards,
                              docs_per_shard=c_loc,
                              n_centroids=n_centroids, n_iters=router_iters,
                              seed=router_seed, valid_docs=valid)
    codebook = None
    if corpus_format == "residual":
        if router is None:
            raise ValueError(
                "corpus_format='residual' needs a centroid codebook: pass "
                "a prebuilt router or n_centroids > 0")
        codebook = np.asarray(router.centroids, np.float32)
    if router is not None:
        router = dataclasses.replace(
            router,
            centroids=put(np.asarray(router.centroids, np.float32),
                          specs["centroids"]),
            shard_mass=put(np.asarray(router.shard_mass, np.float32),
                           specs["shard_mass"]))
    if corpus_format == "bf16":
        embs_dev = put(embs, specs["embs"])
    else:
        qt = quantize(np.asarray(embs, np.float32), corpus_format,
                      codebook=codebook)
        embs_dev = QuantTokens(
            data=put(np.asarray(qt.data), specs["embs"]),
            scales=put(np.asarray(qt.scales), specs["scales"]),
            codes=None if qt.codes is None else
            put(np.asarray(qt.codes), specs["codes"]),
            codebook=None if qt.codebook is None else
            put(np.asarray(qt.codebook), specs["codebook"]))
    return ShardedCorpus(
        embs=embs_dev, mask=put(mask, specs["mask"]),
        mesh=mesh, n_docs=C, n_shards=n_shards, docs_per_shard=c_loc,
        valid_docs=valid, pooled=pooled_dev, router=router,
        fmt=corpus_format)


def _routing_placement(cand_ids: np.ndarray, docs_per_shard: int,
                       n_shards: int, n_local: int):
    """The one gid -> (row, shard, slot) placement both routing functions
    share: candidate gid lands on shard ``gid // docs_per_shard``, packed
    to the front of that shard's slot list in the query's original
    candidate order. Returns (rows, cols, shards, slots) index arrays —
    ``out[rows, shards, slots] = f(cand_ids[rows, cols])`` — so ids and
    per-candidate payloads can never disagree about where a candidate
    went. Vectorized: this runs per served batch on the engine's
    latency-critical path."""
    cand_ids = np.asarray(cand_ids)
    rows, cols = np.nonzero(cand_ids >= 0)
    gids = cand_ids[rows, cols]
    if gids.size and int(gids.max()) >= n_shards * docs_per_shard:
        raise ValueError(
            f"candidate id {int(gids.max())} outside the padded corpus "
            f"({n_shards * docs_per_shard} rows)")
    shards = gids // docs_per_shard
    # Stable grouping key (row, shard): rank within the group = index minus
    # the group's first index, found by searchsorted on the sorted keys.
    key = rows.astype(np.int64) * n_shards + shards
    order = np.argsort(key, kind="stable")
    key_sorted = key[order]
    rank = np.empty_like(order)
    rank[order] = (np.arange(len(order))
                   - np.searchsorted(key_sorted, key_sorted, side="left"))
    if rank.size and int(rank.max()) >= n_local:
        i = rows[int(np.argmax(rank))]
        raise ValueError(
            f"query {int(i)} routes more than n_local={n_local} candidates "
            "to one shard; raise n_local (it may go up to N)")
    return rows, cols, shards, rank


def route_candidates(cand_ids: np.ndarray, docs_per_shard: int,
                     n_shards: int, *, n_local: Optional[int] = None,
                     ) -> np.ndarray:
    """Host-side stage-1 routing: global ids -> per-shard local slots.

    cand_ids (B, N) with -1 padding -> (B, n_shards, n_local), -1 padded:
    candidate gid goes to shard ``gid // docs_per_shard``, PACKED to the
    front of that shard's slot list in the query's original candidate
    order, carrying the local doc row ``gid % docs_per_shard`` as the
    stored value. ``n_local`` defaults to N (the worst case: every
    candidate resident on one shard), keeping the routed shape static per
    candidate bucket — the zero-recompile contract the engine needs.
    """
    cand_ids = np.asarray(cand_ids)
    B, N = cand_ids.shape
    n_local = N if n_local is None else n_local
    rows, cols, shards, slots = _routing_placement(
        cand_ids, docs_per_shard, n_shards, n_local)
    out = np.full((B, n_shards, n_local), -1, np.int32)
    out[rows, shards, slots] = cand_ids[rows, cols] % docs_per_shard
    return out


def route_batch(cand_ids: np.ndarray, payloads, docs_per_shard: int,
                n_shards: int, *, n_local: Optional[int] = None):
    """Route ids plus any number of aligned (B, N, ...) payloads with ONE
    placement computation — what the engine's latency path calls instead
    of ``route_candidates`` + ``route_aligned`` per payload. Returns
    ``(cand_local, [routed payloads...])``."""
    cand_ids = np.asarray(cand_ids)
    B, N = cand_ids.shape
    n_local = N if n_local is None else n_local
    rows, cols, shards, slots = _routing_placement(
        cand_ids, docs_per_shard, n_shards, n_local)
    cand_local = np.full((B, n_shards, n_local), -1, np.int32)
    cand_local[rows, shards, slots] = cand_ids[rows, cols] % docs_per_shard
    routed = []
    for values in payloads:
        values = np.asarray(values)
        out = np.zeros((B, n_shards, n_local) + values.shape[2:],
                       values.dtype)
        out[rows, shards, slots] = values[rows, cols]
        routed.append(out)
    return cand_local, routed


def route_aligned(values: np.ndarray, cand_ids: np.ndarray,
                  cand_local: np.ndarray, docs_per_shard: int) -> np.ndarray:
    """Carry per-candidate payloads (e.g. the (B, N, T) support bounds)
    through the same routing ``route_candidates`` applied to the ids:
    values (B, N, ...) -> (B, n_shards, n_local, ...) aligned with
    ``cand_local``, zero-filled where cand_local is -1."""
    values = np.asarray(values)
    B, n_shards, n_local = cand_local.shape
    rows, cols, shards, slots = _routing_placement(
        cand_ids, docs_per_shard, n_shards, n_local)
    out = np.zeros((B, n_shards, n_local) + values.shape[2:], values.dtype)
    out[rows, shards, slots] = values[rows, cols]
    return out
