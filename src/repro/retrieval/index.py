"""Token-level corpus index for late-interaction retrieval.

Documents are stored padded to a fixed L_max (TPU-static shapes) with a
validity mask; the flattened (C*L, M) token matrix view drives the stage-1
per-query-token kNN. This is the SINGLE-HOST view of the corpus; the
mesh-resident counterpart is ``retrieval/sharded.ShardedCorpus``, and
``retrieval/corpus.py`` is the facade that unifies the two (build either
from one entrypoint, shared candidate-gather helper, centroid router).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenIndex:
    doc_embs: jax.Array     # (C, L, M)
    doc_mask: jax.Array     # (C, L) bool
    doc_lens: jax.Array     # (C,) int32

    @property
    def n_docs(self) -> int:
        return self.doc_embs.shape[0]

    @property
    def max_len(self) -> int:
        return self.doc_embs.shape[1]

    @property
    def dim(self) -> int:
        return self.doc_embs.shape[2]

    def flat_tokens(self) -> Tuple[jax.Array, jax.Array]:
        """(C*L, M) token matrix + (C*L,) owning-doc ids (invalid => -1)."""
        C, L, M = self.doc_embs.shape
        toks = self.doc_embs.reshape(C * L, M)
        owner = jnp.repeat(jnp.arange(C, dtype=jnp.int32), L)
        owner = jnp.where(self.doc_mask.reshape(-1), owner, -1)
        return toks, owner

    def gather_docs(self, doc_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Candidate sub-index: (N, L, M) embeddings + (N, L) mask.
        Negative ids are padding and come back fully masked."""
        from repro.retrieval.corpus import gather_tokens
        return gather_tokens(self.doc_embs, self.doc_mask, doc_ids)


def build_index(doc_embs: np.ndarray, doc_mask: np.ndarray,
                doc_lens: np.ndarray) -> TokenIndex:
    return TokenIndex(doc_embs=jnp.asarray(doc_embs, jnp.float32),
                      doc_mask=jnp.asarray(doc_mask),
                      doc_lens=jnp.asarray(doc_lens, jnp.int32))


def build_index_from_ragged(docs: Sequence[np.ndarray],
                            pad_to: Optional[int] = None) -> TokenIndex:
    """Pack a ragged list of (L_i, M) token arrays into a padded index."""
    lens = np.asarray([d.shape[0] for d in docs], np.int32)
    L = int(pad_to or lens.max())
    M = docs[0].shape[1]
    out = np.zeros((len(docs), L, M), np.float32)
    mask = np.zeros((len(docs), L), bool)
    for i, d in enumerate(docs):
        n = min(d.shape[0], L)
        out[i, :n] = d[:n]
        mask[i, :n] = True
    return build_index(out, mask, np.minimum(lens, L))
