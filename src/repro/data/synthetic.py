"""Synthetic late-interaction corpora with controllable relevance structure.

BEIR/REAL-MM-RAG cannot ship in this container, so experiments run on a
topic-model generator that preserves the statistics that matter for
Col-Bandit: (i) normalized token embeddings (cosine MaxSim in [-1, 1], and
in ~[0, 1] for matching topics), (ii) a small set of truly relevant
documents per query whose MaxSim rows dominate, (iii) a long tail of
near-miss distractors that cluster near the decision boundary (these are
what make adaptive allocation pay off), and (iv) variable document lengths.

Every generator is seeded and returns plain numpy (converted lazily to jnp
by consumers) so the data pipeline stays deterministic across restarts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RetrievalDataset:
    doc_embs: np.ndarray       # (C, L, M) float32, L2-normalized tokens
    doc_mask: np.ndarray       # (C, L) bool
    doc_lens: np.ndarray       # (C,) int32
    queries: np.ndarray        # (Q, T, M) float32
    qrels: np.ndarray          # (Q, C) bool — relevance labels
    topics: np.ndarray         # (K_topics, M)

    @property
    def n_docs(self) -> int:
        return self.doc_embs.shape[0]

    @property
    def n_queries(self) -> int:
        return self.queries.shape[0]


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def make_retrieval_dataset(
    *,
    n_docs: int = 512,
    n_queries: int = 16,
    n_topics: int = 32,
    doc_len: int = 96,
    min_doc_len: int = 24,
    query_len: int = 32,
    dim: int = 128,
    relevant_per_query: int = 4,
    distractors_per_query: int = 24,
    topic_strength: float = 0.7,
    distractor_strength: float = 0.55,
    seed: int = 0,
) -> RetrievalDataset:
    """Topic-model corpus.

    Each doc draws a primary topic; its tokens mix the topic direction with
    noise. A query targets one topic; `relevant_per_query` docs share it
    strongly, `distractors_per_query` share it weakly (borderline scores).
    """
    rng = np.random.default_rng(seed)
    topics = _normalize(rng.standard_normal((n_topics, dim)).astype(np.float32))

    doc_topic = rng.integers(0, n_topics, size=n_docs)
    doc_lens = rng.integers(min_doc_len, doc_len + 1, size=n_docs).astype(np.int32)
    noise = rng.standard_normal((n_docs, doc_len, dim)).astype(np.float32)
    mix = rng.uniform(0.1, 0.5, size=(n_docs, doc_len, 1)).astype(np.float32)
    doc_embs = _normalize(mix * topics[doc_topic][:, None, :] + (1 - mix) * noise * 0.4)
    doc_mask = np.arange(doc_len)[None, :] < doc_lens[:, None]
    doc_embs = np.where(doc_mask[:, :, None], doc_embs, 0.0).astype(np.float32)

    queries = np.zeros((n_queries, query_len, dim), np.float32)
    qrels = np.zeros((n_queries, n_docs), bool)
    for q in range(n_queries):
        topic = rng.integers(0, n_topics)
        qn = rng.standard_normal((query_len, dim)).astype(np.float32)
        # Real queries mix on-topic terms with generic/function tokens, so
        # per-row MaxSim values VARY — the within-row variance that the
        # empirical-Bernstein radius feeds on. ~25% of tokens are pure noise
        # ("stopwords"), the rest span weak-to-strong topicality.
        qmix = rng.uniform(0.15, 0.95, size=(query_len, 1)).astype(np.float32)
        noise_tok = rng.random(query_len) < 0.25
        qmix[noise_tok] = 0.0
        queries[q] = _normalize(qmix * topics[topic][None, :] + (1 - qmix) * qn * 0.4)

        # plant relevant docs: strengthen topic alignment of a random subset
        rel = rng.choice(n_docs, size=relevant_per_query, replace=False)
        for d in rel:
            ln = doc_lens[d]
            n_strong = max(2, int(topic_strength * min(ln, 16)))
            pos = rng.choice(ln, size=n_strong, replace=False)
            tn = rng.standard_normal((n_strong, dim)).astype(np.float32)
            doc_embs[d, pos] = _normalize(
                topic_strength * topics[topic][None, :] + (1 - topic_strength) * tn * 0.3)
        qrels[q, rel] = True

        # borderline distractors: weakly aligned, crowd the boundary
        pool = np.setdiff1d(np.arange(n_docs), rel)
        dis = rng.choice(pool, size=min(distractors_per_query, pool.size),
                         replace=False)
        for d in dis:
            ln = doc_lens[d]
            n_weak = max(1, int(0.3 * min(ln, 12)))
            pos = rng.choice(ln, size=n_weak, replace=False)
            tn = rng.standard_normal((n_weak, dim)).astype(np.float32)
            doc_embs[d, pos] = _normalize(
                distractor_strength * topics[topic][None, :]
                + (1 - distractor_strength) * tn * 0.4)

    doc_embs = np.where(doc_mask[:, :, None], doc_embs, 0.0).astype(np.float32)
    return RetrievalDataset(doc_embs=doc_embs, doc_mask=doc_mask,
                            doc_lens=doc_lens, queries=queries, qrels=qrels,
                            topics=topics)


def make_mixed_difficulty_h(n_queries: int, n_docs: int, n_tokens: int, *,
                            k: int = 10, hard_frac: float = 0.25,
                            seed: int = 0) -> np.ndarray:
    """Oracle MaxSim tensor H (Q, N, T) with a controlled difficulty mix.

    Most queries have their top-k separated by a wide margin at rank k
    (the bandit separates them in few rounds); the last ``hard_frac`` of
    queries have ~2k near-tied contenders straddling rank k (many rounds).
    This is the straggler mix that makes lockstep reveal waste visible —
    shared by the frontier-retirement tests and the reveal benchmark so
    the workload they pin is one and the same.
    """
    rng = np.random.default_rng(seed)
    H = rng.uniform(0.1, 0.4,
                    (n_queries, n_docs, n_tokens)).astype(np.float32)
    n_hard = int(round(hard_frac * n_queries))   # 0.0 -> all-easy batch
    for q in range(n_queries):
        if q < n_queries - n_hard:               # easy: clear top-k margin
            H[q, rng.choice(n_docs, k, replace=False)] += 0.5
        else:                                    # hard: 2k near-ties
            H[q, rng.choice(n_docs, 2 * k, replace=False)] += 0.3
    return np.clip(H, 0.0, 1.0)
