"""Thread-lockset race lint for the serving engine (and any class that
declares its threading discipline).

A module opts in by declaring two module-level LITERAL tables (read with
``ast.literal_eval`` — the pass never imports the target code):

``THREAD_ENTRY_POINTS = {"group": ("method", ...), ...}``
    The methods each thread group enters the class through — e.g. the
    engine's ``caller`` (public API), ``admit``/``dispatch``/``stream``
    (pipeline threads), ``supervisor`` (watchdog callbacks).

``GUARDED_BY = {"_attr": "_lock_name" | "internal" | "atomic" |
               "ordered" | "init", ...}``
    The guard discipline per shared attribute. A lock name is VERIFIED:
    every write/mutation outside ``__init__`` must occur under
    ``with self.<lock>``. The special values document non-lock
    disciplines: ``internal`` (the object takes its own lock),
    ``atomic`` (single GIL-atomic reference/item assignment), ``ordered``
    (accesses serialized by thread join/restart ordering), ``init``
    (written only before the serving threads exist).

The pass builds, per thread group, the set of ``self.*`` attributes the
group's reachable methods read, write (plain/aug assignment), or mutate
(``self.x[k] = v``, ``self.x.append(...)`` and friends), then fails any
attribute that (a) is written and touched by >= 2 groups, (b) has no
``GUARDED_BY`` entry, and (c) is not consistently accessed under one
``with self.<lock>`` — plus any write that escapes its declared lock.

Attributes bound to ``threading.Lock/RLock/Condition/Event``,
``queue.Queue`` or ``itertools.count`` in ``__init__`` are auto-safe, as
are attributes never written outside ``__init__``.

:class:`repro.analysis.recorder.ThreadAccessRecorder` is the runtime twin
used by the chaos soak.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.lint import Violation, _chain

GUARD_MODES = ("internal", "atomic", "ordered", "init")

_SAFE_CTORS = {"Lock", "RLock", "Condition", "Event", "Semaphore",
               "BoundedSemaphore", "Barrier", "Queue", "LifoQueue",
               "PriorityQueue", "SimpleQueue", "count"}
_MUTATORS = {"append", "appendleft", "extend", "extendleft", "pop",
             "popleft", "popitem", "remove", "clear", "add", "discard",
             "update", "insert", "setdefault", "sort", "reverse"}
_FUNC = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclasses.dataclass(frozen=True)
class Access:
    attr: str
    kind: str            # "read" | "write" | "mutate"
    locks: frozenset     # self.<lock> contexts held at the access
    method: str
    line: int


def _literal_table(tree: ast.Module, name: str) -> Optional[dict]:
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name):
            return ast.literal_eval(node.value)
    return None


class _MethodScan(ast.NodeVisitor):
    """Accesses + self-call edges of one method body, tracking the
    ``with self.<lock>:`` context stack."""

    def __init__(self, method: str, lock_attrs: Set[str]):
        self.method = method
        self.lock_attrs = lock_attrs
        self.accesses: List[Access] = []
        self.calls: Set[str] = set()
        self._held: List[str] = []

    def _self_attr(self, node: ast.AST) -> Optional[str]:
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        return None

    def _note(self, attr: str, kind: str, line: int) -> None:
        self.accesses.append(Access(attr, kind, frozenset(self._held),
                                    self.method, line))

    def visit_With(self, node: ast.With) -> None:
        held = []
        for item in node.items:
            attr = self._self_attr(item.context_expr)
            if attr is not None and attr in self.lock_attrs:
                held.append(attr)
        self._held.extend(held)
        self.generic_visit(node)
        for _ in held:
            self._held.pop()

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = self._self_attr(node)
        if attr is None:
            self.generic_visit(node)
            return
        parent = getattr(node, "_repro_parent", None)
        if isinstance(node.ctx, (ast.Store, ast.Del)):
            self._note(attr, "write", node.lineno)
        elif isinstance(parent, ast.Subscript) and isinstance(
                parent.ctx, (ast.Store, ast.Del)):
            self._note(attr, "mutate", node.lineno)
        elif (isinstance(parent, ast.Attribute)
              and parent.attr in _MUTATORS
              and isinstance(getattr(parent, "_repro_parent", None),
                             ast.Call)):
            self._note(attr, "mutate", node.lineno)
            self.calls.add(attr)          # may be a method ref; filtered later
        else:
            self._note(attr, "read", node.lineno)
            self.calls.add(attr)          # method refs double as call edges
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # super().m(...) edges.
        f = node.func
        if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Call)
                and isinstance(f.value.func, ast.Name)
                and f.value.func.id == "super"):
            self.calls.add(f.attr)
        self.generic_visit(node)


def check_source(src: str, path: str) -> List[Violation]:
    tree = ast.parse(src, filename=path)
    entry_points = _literal_table(tree, "THREAD_ENTRY_POINTS")
    if not entry_points:
        return []
    guarded: Dict[str, str] = _literal_table(tree, "GUARDED_BY") or {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._repro_parent = node  # type: ignore[attr-defined]

    # Merge every class in the module: the async engine subclasses the
    # sync engine in the same file, and entry points name methods of both.
    methods: Dict[str, List[ast.AST]] = {}
    for node in tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        for item in node.body:
            if isinstance(item, _FUNC):
                methods.setdefault(item.name, []).append(item)

    # Auto-safe attributes: lock/queue/counter constructors in __init__.
    lock_attrs: Set[str] = set()
    for init in methods.get("__init__", []):
        for node in ast.walk(init):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and _chain(node.value.func)[-1] in _SAFE_CTORS):
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        lock_attrs.add(t.attr)
    lock_names = {g for g in guarded.values() if g not in GUARD_MODES}
    lock_attrs |= lock_names

    scans: Dict[str, List[_MethodScan]] = {}
    for name, defs in methods.items():
        for d in defs:
            scan = _MethodScan(name, lock_names | lock_attrs)
            scan.visit(d)
            scans.setdefault(name, []).append(scan)

    def reachable(entries: Tuple[str, ...]) -> Set[str]:
        seen: Set[str] = set()
        stack = [m for m in entries if m in scans]
        while stack:
            m = stack.pop()
            if m in seen:
                continue
            seen.add(m)
            for scan in scans[m]:
                for callee in scan.calls:
                    if callee in scans and callee not in seen:
                        stack.append(callee)
        return seen

    # attr -> group -> accesses (data attrs only: method names excluded).
    by_attr: Dict[str, Dict[str, List[Access]]] = {}
    for group, entries in entry_points.items():
        for m in reachable(tuple(entries)):
            for scan in scans[m]:
                for acc in scan.accesses:
                    if acc.attr in methods or acc.attr in lock_attrs:
                        continue
                    by_attr.setdefault(acc.attr, {}).setdefault(
                        group, []).append(acc)

    out: List[Violation] = []
    for attr in sorted(by_attr):
        groups = by_attr[attr]
        writes = [a for g in groups.values() for a in g
                  if a.kind in ("write", "mutate")
                  and a.method != "__init__"]
        guard = guarded.get(attr)
        if guard is not None and guard not in GUARD_MODES:
            escaped = [a for a in writes if guard not in a.locks]
            for a in escaped:
                out.append(Violation(
                    path, a.line, "lockset",
                    f"self.{attr} written in {a.method}() outside its "
                    f"declared guard self.{guard}"))
            continue
        if guard in GUARD_MODES:
            continue
        if len(groups) < 2 or not writes:
            continue                       # single-threaded or init-only
        all_accesses = [a for g in groups.values() for a in g
                        if a.method != "__init__"]
        common = frozenset.intersection(
            *[a.locks for a in all_accesses]) if all_accesses else frozenset()
        if common:
            continue                       # consistently locked, undeclared
        a = writes[0]
        out.append(Violation(
            path, a.line, "lockset",
            f"self.{attr} is shared by thread groups "
            f"{sorted(groups)} with no GUARDED_BY entry and no "
            "consistent lock"))
    # One method reachable from several groups records its accesses once
    # per group — report each (line, message) once.
    seen: Set[Tuple[int, str]] = set()
    deduped = []
    for v in sorted(out, key=lambda v: (v.line, v.msg)):
        if (v.line, v.msg) not in seen:
            seen.add((v.line, v.msg))
            deduped.append(v)
    return deduped


def check_file(path: str) -> List[Violation]:
    with open(path, "r", encoding="utf-8") as f:
        return check_source(f.read(), path)


if __name__ == "__main__":
    import sys
    bad = [v for p in sys.argv[1:] for v in check_file(p)]
    for v in bad:
        print(v.render())
    sys.exit(1 if bad else 0)
