"""Static analysis & compile-contract auditing for the repro codebase.

Three passes, all stdlib-only (no jax import at analysis time):

* :mod:`repro.analysis.hlo_audit` — the compile-contract auditor: parses
  each AOT-warmed executable's optimized HLO and asserts the serving
  contracts (no host callbacks, no f64, collective traffic within the
  K-sized scorecard budget, peak buffers bounded). Wired into the engine
  as ``EngineConfig(audit=True)``.
* :mod:`repro.analysis.lint` — trace-safety AST lint encoding this
  repo's real bug history (PRNG ``key(seed + x)`` aliasing, host syncs
  under trace, bare kernel asserts, ...). Run as
  ``python -m repro.analysis.lint src/``.
* :mod:`repro.analysis.locks` — thread-lockset race lint over classes
  that declare ``THREAD_ENTRY_POINTS`` / ``GUARDED_BY`` tables (the
  serving engine); :mod:`repro.analysis.recorder` is its runtime twin,
  a debug sanitizer the chaos soak can run under.

The machine-checked invariant catalog lives in ``CONTRACTS.md``.
"""
from repro.analysis.hlo_audit import (AuditError, AuditSpec,  # noqa: F401
                                      audit_executable, audit_hlo_text,
                                      collective_bytes,
                                      scorecard_budget_bytes)
