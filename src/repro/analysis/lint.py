"""Trace-safety AST lint: repo-specific rules over ``ast``, no new deps.

Every rule encodes a bug class this repo has actually shipped (or nearly
shipped) — see CONTRACTS.md for the catalog. Run as::

    PYTHONPATH=src python -m repro.analysis.lint src/          # gate
    PYTHONPATH=src python -m repro.analysis.lint tests/ --report-only

Rules
-----
``prng-aliasing``
    ``jax.random.key(seed + x)`` / ``PRNGKey(seed + x)`` with a
    non-constant arithmetic argument: nearby seeds alias streams across
    engines/tests. Derive with ``jax.random.fold_in(key(seed), x)``.
``traced-truthiness``
    Python ``if``/``while``/``assert``/ternary on a jnp/lax call result
    inside a traced function — a TracerBoolConversionError at best, a
    silently-wrong constant at worst.
``traced-cast``
    ``float()``/``int()``/``bool()``/``.item()`` on a jnp/lax call result
    inside a traced function.
``host-sync-in-trace``
    ``np.asarray``/``np.array``/``jax.device_get``/``block_until_ready``
    inside a traced function (round-loop bodies, jitted steps): a forced
    device sync (or trace error) in compiled code.
``time-in-trace``
    ``time.time()``/``perf_counter()``/``monotonic()`` inside a traced
    function — traces once, constant-folds forever.
``kernel-assert``
    Bare ``assert`` in ``kernels/``: stripped under ``python -O`` and
    useless inside a traced kernel. Raise ``ValueError`` at the host
    entry point instead.
``mutable-default``
    Mutable default argument (list/dict/set literal or constructor).
``lockset``
    From :mod:`repro.analysis.locks`: a thread-shared engine attribute
    with no declared guard (files declaring ``THREAD_ENTRY_POINTS``).

A "traced function" is one passed to ``lax.while_loop/fori_loop/scan/
cond/switch/map``, ``jit``/``vmap``/``pmap``/``shard_map``/
``pallas_call`` (or decorated with the jit family), plus everything
nested inside one.

Suppression: append ``# repro: noqa-<rule>`` to the offending line. The
gate counts suppressions — CI runs with ``--max-suppressions 0`` plus the
committed (empty) baseline ``src/repro/analysis/lint_baseline.txt``, so a
suppression needs an explicit baseline entry to merge.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Sequence, Set, Tuple

RULES = {
    "prng-aliasing": "key(seed + x) aliases streams; use fold_in",
    "traced-truthiness": "Python truthiness on a traced value",
    "traced-cast": "float()/int()/bool()/.item() on a traced value",
    "host-sync-in-trace": "np.asarray/device_get/block_until_ready in trace",
    "time-in-trace": "wall-clock read under trace",
    "kernel-assert": "bare assert in kernels/ (raise ValueError)",
    "mutable-default": "mutable default argument",
    "lockset": "thread-shared attribute without a declared guard",
}

NOQA = "# repro: noqa-"
DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "lint_baseline.txt")

_TRACER_CALLEES = {"while_loop", "fori_loop", "scan", "cond", "switch",
                   "map", "jit", "pjit", "vmap", "pmap", "shard_map",
                   "pallas_call", "checkpoint", "remat", "named_scope"}
_JIT_FAMILY = {"jit", "pjit", "vmap", "pmap", "shard_map", "checkpoint",
               "remat", "custom_vjp", "custom_jvp"}
# Which positional args of each control-flow tracer are function-valued.
_FN_ARG_SLOTS = {"while_loop": (0, 1), "fori_loop": (2,), "scan": (0,),
                 "map": (0,), "cond": (1, 2), "switch": None}
# jnp/lax functions that return genuine Python values at trace time.
_HOST_SAFE = {"issubdtype", "iinfo", "finfo", "result_type",
              "promote_types", "can_cast", "isdtype", "dtype", "ndim",
              "broadcast_shapes"}


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    msg: str
    suppressed: bool = False

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}]{tag} {self.msg}"


def _chain(node: ast.AST) -> Tuple[str, ...]:
    """Dotted-name chain of an expression: jax.lax.scan -> (jax,lax,scan)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        parts.append("?")
    return tuple(reversed(parts))


def _is_device_call(node: ast.AST) -> bool:
    """A call rooted at jnp / lax / jax.numpy / jax.lax whose result is a
    traced array (not a host-safe dtype/shape query)."""
    if not isinstance(node, ast.Call):
        return False
    c = _chain(node.func)
    if c[-1] in _HOST_SAFE:
        return False
    return (c[0] in ("jnp", "lax")
            or (len(c) >= 2 and c[0] == "jax" and c[1] in ("numpy", "lax")))


def _set_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._repro_parent = node  # type: ignore[attr-defined]


_FUNC = (ast.FunctionDef, ast.AsyncFunctionDef)


def _collect_traced(tree: ast.Module) -> Set[ast.AST]:
    """The set of FunctionDef nodes whose bodies run under jax tracing
    (see module docstring for the definition)."""
    defs_by_scope: Dict[Optional[ast.AST], Dict[str, ast.AST]] = {}
    scope_of: Dict[ast.AST, Optional[ast.AST]] = {}

    def walk(node: ast.AST, scope: Optional[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FUNC):
                defs_by_scope.setdefault(scope, {})[child.name] = child
                scope_of[child] = scope
                walk(child, child)
            else:
                walk(child, scope)

    walk(tree, None)

    def resolve(name: str, scope: Optional[ast.AST]) -> Optional[ast.AST]:
        while True:
            fn = defs_by_scope.get(scope, {}).get(name)
            if fn is not None:
                return fn
            if scope is None:
                return None
            scope = scope_of.get(scope)

    traced: Set[ast.AST] = set()

    def mark(fn: ast.AST) -> None:
        if fn in traced:
            return
        traced.add(fn)
        for child in ast.walk(fn):          # nested defs trace too
            if isinstance(child, _FUNC):
                traced.add(child)

    # Seed: decorators of the jit family.
    for fn in scope_of:
        for dec in fn.decorator_list:
            target = dec
            if (isinstance(dec, ast.Call) and dec.args
                    and _chain(dec.func)[-1] == "partial"):
                target = dec.args[0]
            elif isinstance(dec, ast.Call):
                target = dec.func
            if _chain(target)[-1] in _JIT_FAMILY:
                mark(fn)

    # Seed: function-valued arguments of tracer calls.
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee_chain = _chain(node.func)
        callee = callee_chain[-1]
        if callee not in _TRACER_CALLEES:
            continue
        if callee in ("map", "cond", "switch", "checkpoint", "remat") and (
                len(callee_chain) < 2
                or callee_chain[0] not in ("jax", "lax")):
            continue          # builtin map() / a local named cond(), etc.
        slots = _FN_ARG_SLOTS.get(callee, (0,))
        enclosing = node
        while (enclosing is not None
               and not isinstance(enclosing, _FUNC)):
            enclosing = getattr(enclosing, "_repro_parent", None)
        args = (node.args if slots is None
                else [node.args[i] for i in slots if i < len(node.args)])
        for arg in args:
            if isinstance(arg, ast.Name):
                fn = resolve(arg.id, enclosing)
                if fn is not None:
                    mark(fn)

    # Deliberately NOT transitive through plain calls: helpers invoked
    # from traced code often do legitimate host math on static values
    # (shape/offset tables via np) — flagging those drowns the signal.
    return traced


def _prng_violations(tree: ast.Module, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        c = _chain(node.func)
        is_key = (c[-1] == "PRNGKey"
                  or (c[-1] == "key" and len(c) >= 2
                      and c[-2] in ("random", "jr")))
        if not is_key:
            continue
        arg = node.args[0]
        if not isinstance(arg, ast.BinOp):
            continue
        if any(isinstance(leaf, (ast.Name, ast.Attribute, ast.Call))
               for leaf in ast.walk(arg)):
            out.append(Violation(
                path, node.lineno, "prng-aliasing",
                "key(seed + x) aliases PRNG streams across nearby seeds; "
                "use jax.random.fold_in(jax.random.key(seed), x)"))
    return out


def _mutable_default_violations(tree: ast.Module,
                                path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, _FUNC):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in ("list", "dict", "set"))
            if mutable:
                out.append(Violation(
                    path, d.lineno, "mutable-default",
                    f"mutable default argument in {node.name}(); "
                    "default to None and build inside"))
    return out


def _kernel_assert_violations(tree: ast.Module,
                              path: str) -> List[Violation]:
    if f"{os.sep}kernels{os.sep}" not in os.path.abspath(path):
        return []
    return [Violation(path, node.lineno, "kernel-assert",
                      "bare assert in kernels/ vanishes under python -O; "
                      "raise ValueError")
            for node in ast.walk(tree) if isinstance(node, ast.Assert)]


def _traced_body_violations(tree: ast.Module, path: str) -> List[Violation]:
    out: List[Violation] = []
    traced = _collect_traced(tree)
    seen: Set[Tuple[int, str]] = set()

    def add(line: int, rule: str, msg: str) -> None:
        if (line, rule) not in seen:
            seen.add((line, rule))
            out.append(Violation(path, line, rule, msg))

    for fn in traced:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                test = node.test
                for sub in ast.walk(test):
                    if _is_device_call(sub):
                        add(node.lineno, "traced-truthiness",
                            f"Python truthiness on {_dot(sub)} result in "
                            f"traced {fn.name}(); use jnp.where/lax.cond")
            if not isinstance(node, ast.Call):
                continue
            c = _chain(node.func)
            if (c[-1] in ("float", "int", "bool") and len(c) == 1
                    and len(node.args) == 1
                    and _is_device_call(node.args[0])):
                add(node.lineno, "traced-cast",
                    f"{c[-1]}() on a traced value in {fn.name}()")
            if (c[-1] == "item" and isinstance(node.func, ast.Attribute)
                    and not node.args):
                add(node.lineno, "traced-cast",
                    f".item() forces a host sync in traced {fn.name}()")
            if (c[-1] in ("asarray", "array", "copy")
                    and c[0] in ("np", "numpy")) or \
                    (c[-1] == "device_get" and c[0] == "jax") or \
                    c[-1] == "block_until_ready":
                add(node.lineno, "host-sync-in-trace",
                    f"{'.'.join(c)} in traced {fn.name}() forces a host "
                    "round-trip")
            if c[0] == "time" and c[-1] in ("time", "perf_counter",
                                            "monotonic"):
                add(node.lineno, "time-in-trace",
                    f"{'.'.join(c)}() in traced {fn.name}() constant-folds "
                    "at trace time")
    return out


def _dot(call: ast.AST) -> str:
    return ".".join(_chain(call.func)) if isinstance(call, ast.Call) else "?"


def lint_source(src: str, path: str) -> List[Violation]:
    """All rule violations for one file's source, with per-line noqa
    suppression applied (suppressed violations are returned flagged, so
    the gate can count them)."""
    tree = ast.parse(src, filename=path)
    _set_parents(tree)
    raw = (_prng_violations(tree, path)
           + _mutable_default_violations(tree, path)
           + _kernel_assert_violations(tree, path)
           + _traced_body_violations(tree, path))
    srclines = src.splitlines()
    out = []
    for v in raw:
        line = srclines[v.line - 1] if 0 < v.line <= len(srclines) else ""
        out.append(dataclasses.replace(v, suppressed=NOQA + v.rule in line))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_file(path: str) -> List[Violation]:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    violations = lint_source(src, path)
    if "THREAD_ENTRY_POINTS" in src:
        from repro.analysis import locks
        violations += locks.check_source(src, path)
    return violations


def iter_py_files(paths: Sequence[str],
                  include_fixtures: bool = False) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)                      # explicit file: always lint
            continue
        for root, dirs, files in os.walk(p):
            if not include_fixtures and "fixtures" in root.split(os.sep):
                dirs[:] = []
                continue
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(root, f))
    return sorted(set(out))


def load_baseline(path: str) -> Set[Tuple[str, str]]:
    """Baseline entries are ``<path-suffix>:<rule>`` lines ('#' comments
    allowed); a violation matches when its rule matches and its path ends
    with the entry's path suffix."""
    entries: Set[Tuple[str, str]] = set()
    if not os.path.exists(path):
        return entries
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            fpath, _, rule = line.rpartition(":")
            entries.add((fpath.replace("\\", "/"), rule))
    return entries


def _baselined(v: Violation, baseline: Set[Tuple[str, str]]) -> bool:
    vpath = v.path.replace(os.sep, "/")
    return any(rule == v.rule and vpath.endswith(fpath)
               for fpath, rule in baseline)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific trace-safety + thread-lockset lint")
    ap.add_argument("paths", nargs="*", default=["src"])
    ap.add_argument("--report-only", action="store_true",
                    help="print violations but exit 0")
    ap.add_argument("--baseline", default=None,
                    help="known-violation file (path:rule lines); "
                    f"default {DEFAULT_BASELINE}")
    ap.add_argument("--max-suppressions", type=int, default=None,
                    help="fail when more than N '# repro: noqa-*' "
                    "suppressions are in effect")
    ap.add_argument("--include-fixtures", action="store_true",
                    help="also lint the analysis fixtures (each one "
                    "deliberately violates a rule)")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    baseline = load_baseline(args.baseline or DEFAULT_BASELINE)
    files = iter_py_files(args.paths or ["src"], args.include_fixtures)
    active: List[Violation] = []
    suppressed: List[Violation] = []
    baselined: List[Violation] = []
    for path in files:
        for v in lint_file(path):
            if v.suppressed:
                suppressed.append(v)
            elif _baselined(v, baseline):
                baselined.append(v)
            else:
                active.append(v)

    if args.as_json:
        print(json.dumps({
            "files": len(files),
            "violations": [dataclasses.asdict(v) for v in active],
            "suppressed": [dataclasses.asdict(v) for v in suppressed],
            "baselined": [dataclasses.asdict(v) for v in baselined],
        }, indent=1))
    else:
        for v in active + suppressed:
            print(v.render())
        print(f"{len(files)} files: {len(active)} violation(s), "
              f"{len(suppressed)} suppressed, {len(baselined)} baselined")

    failed = bool(active)
    if (args.max_suppressions is not None
            and len(suppressed) > args.max_suppressions):
        print(f"suppression budget exceeded: {len(suppressed)} > "
              f"{args.max_suppressions}")
        failed = True
    if args.report_only:
        return 0
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
