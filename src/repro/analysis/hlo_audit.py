"""Compile-contract auditor over optimized HLO text (plus the roofline's
collective byte accounting, promoted here from ``launch/hlo_analysis``).

``compiled.cost_analysis()`` exposes FLOPs and bytes-accessed but NOT
collective traffic — we parse the optimized HLO and sum the operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Sizes are per-replica operand bytes, i.e. the payload a
single device injects into the interconnect for that op (the standard
roofline convention: collective_time ~= bytes / link_bw, treating ring
algorithms' 2(n-1)/n factor as ~1).

On top of the accounting sits the auditor: :func:`audit_executable` walks
one AOT executable's optimized HLO and raises :class:`AuditError` (with the
offending HLO lines as provenance) when a serving contract is broken:

``hlo-host-sync``
    A host round-trip inside a compiled step: infeed/outfeed, send/recv,
    or a custom-call that either declares a side effect (``io_callback``,
    ``jax.debug.*`` lower to these) or targets a host callback. Benign
    backend custom-calls (CPU's ``TopK``) are side-effect-free and pass.
``hlo-f64``
    Any f64/c128 buffer — the pipeline is bf16-resident with f32
    accumulation; a double sneaking in is always an accident.
``hlo-corpus-promotion``
    A low-precision (bf16/f16) corpus entering the executable as an f32
    parameter: someone promoted the resident corpus before lowering.
    (In-trace tile upcasts are the f32-accumulation contract and XLA may
    legally hoist them; residency is audited at the program boundary.)
``hlo-int8-residency``
    The compressed-corpus twin of the promotion rule: a quantized (s8)
    corpus must cross the ENTRY boundary AT int8 — the audit demands a
    corpus-sized s8 entry parameter and rejects any corpus-sized f32/bf16
    entry parameter (someone dequantized the payload before lowering,
    which re-inflates HBM residency and defeats the in-kernel dequant
    contract). In-kernel reconstruction to f32 tiles is expected and
    invisible here: only the program boundary is audited.
``hlo-collective-budget``
    Collective traffic above the declared byte budget. For sharded
    serving steps the budget is the scorecard contract: per-shard top-K
    scores + ids all-gathered plus two scalar psums —
    :func:`scorecard_budget_bytes`.
``hlo-peak-buffer``
    ``memory_analysis().temp_size_in_bytes`` above the declared bound
    (the materialized-similarity-tensor failure mode).

This module is stdlib-only (no jax import): it must be importable by the
lint CLI and CI without an accelerator runtime.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    # Zero-width HLO types that legally appear in shape position.
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\sparameter\(")
_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')

# Host-callback custom-call targets (jax callbacks / debug prints across
# backends). Matched as substrings of custom_call_target.
_CALLBACK_TARGETS = ("callback", "py_func", "host")


def _shape_bytes(dtype: str, dims: str) -> int:
    """Bytes of one ``dtype[dims]`` HLO shape token; ``dims`` is the
    comma-joined dim list ("" for a scalar ``[]``). Unknown dtypes raise —
    a silent 0 would undercount collective traffic and let a budget audit
    pass vacuously."""
    if dtype not in _DTYPE_BYTES:
        raise ValueError(f"unknown HLO dtype {dtype!r} in shape "
                         f"{dtype}[{dims}] — add it to _DTYPE_BYTES")
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind (+ 'total').

    ``-done`` ops are skipped so async pairs aren't double counted; tuple
    outputs count every element shape on the line before the op name."""
    out: Dict[str, int] = defaultdict(int)
    for kind, nbytes, _ in collective_lines(hlo_text):
        out[kind] += nbytes
        out["total"] += nbytes
    return dict(out)


def collective_lines(hlo_text: str) -> List[Tuple[str, int, str]]:
    """Every collective op line as (kind, payload_bytes, hlo_line) — the
    provenance-carrying form of :func:`collective_bytes`."""
    out: List[Tuple[str, int, str]] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped or "-done." in stripped:
            continue
        hit = None
        for coll in _COLLECTIVES:
            if f" {coll}(" in stripped or f" {coll}-start(" in stripped:
                hit = coll
                break
        if hit is None:
            continue
        lhs = stripped.split(f" {hit}")[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        out.append((hit, nbytes, stripped))
    return out


def flops_and_bytes(compiled) -> Dict[str, float]:
    """Pull FLOPs / bytes-accessed out of compiled.cost_analysis() across
    jax versions (dict vs list-of-dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    return {"hlo_flops": flops, "hlo_bytes": nbytes}


def peak_buffer_bytes(compiled) -> float:
    """Peak temporary-buffer footprint of a compiled executable.

    ``temp_size_in_bytes`` is XLA's allocation for every intermediate the
    program materializes — the number that blows up when a formulation
    keeps a (B, N, L, T) similarity tensor live instead of streaming it.
    Used by the reveal benchmark / tests to assert the dense serving step
    stays under the materialized-intermediate threshold."""
    return float(compiled.memory_analysis().temp_size_in_bytes)


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = float(getattr(ma, k))
        except AttributeError:
            pass
    return out


# ---------------------------------------------------------------------------
# The auditor
# ---------------------------------------------------------------------------

def scorecard_budget_bytes(batch: int, shards: int, topk: int) -> int:
    """The one-shard_map pipeline's cross-shard traffic contract: per
    shard, a (B, K) f32 score + (B, K) s32 gid scorecard all-gather, plus
    two f32[B] scalar psums (revealed-cell and total-cell counts)."""
    return 2 * batch * shards * topk * 4 + 2 * batch * 4


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """What one executable is allowed to do.

    ``collective_budget``: max collective payload bytes (0 = none allowed,
    None = unaudited — e.g. the host stage-1 path, whose corpus
    all-gather is the documented exception). ``peak_bytes``: max
    ``temp_size_in_bytes`` (None = unaudited). ``corpus_dtype`` +
    ``corpus_elems``: the resident corpus's HLO dtype and PAYLOAD element
    count, for the boundary-residency rules — ``bf16``/``f16`` arms the
    promotion rule, ``s8`` arms the int8-residency rule (the compressed
    corpus must enter the program as an s8 parameter, never widened)."""

    collective_budget: Optional[int] = None
    peak_bytes: Optional[int] = None
    corpus_dtype: Optional[str] = None
    corpus_elems: int = 0


@dataclasses.dataclass
class AuditReport:
    label: str
    collective_total: int
    collective: Dict[str, int]
    peak_bytes: Optional[float] = None


class AuditError(RuntimeError):
    """A compiled executable broke a serving contract. ``rule`` is the
    machine-readable id; ``lines`` carry the offending HLO ops."""

    def __init__(self, rule: str, label: str, detail: str,
                 lines: Optional[List[str]] = None):
        self.rule = rule
        self.label = label
        self.lines = list(lines or [])
        prov = "".join(f"\n    {ln[:200]}" for ln in self.lines[:4])
        more = (f"\n    ... and {len(self.lines) - 4} more"
                if len(self.lines) > 4 else "")
        super().__init__(f"[{rule}] {label}: {detail}{prov}{more}")


def _host_sync_lines(hlo_text: str) -> List[str]:
    out = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if any(f" {op}(" in stripped for op in
               ("infeed", "outfeed", "send", "recv", "send-done",
                "recv-done")):
            out.append(stripped)
            continue
        if "custom-call" not in stripped:
            continue
        if "custom_call_has_side_effect=true" in stripped:
            out.append(stripped)
            continue
        m = _TARGET_RE.search(stripped)
        if m and any(pat in m.group(1).lower()
                     for pat in _CALLBACK_TARGETS):
            out.append(stripped)
    return out


def _f64_lines(hlo_text: str) -> List[str]:
    return [ln.strip() for ln in hlo_text.splitlines()
            if ("f64[" in ln or "c128[" in ln) and "=" in ln]


def _entry_lines(hlo_text: str) -> List[str]:
    """The ENTRY computation's op lines only. Fusion computations carry
    their own ``parameter(N)`` lines for every operand — including legally
    hoisted in-trace f32 tiles — so boundary-residency rules must not see
    them."""
    out, inside = [], False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            inside = True
            continue
        if inside:
            if line.startswith("}"):
                inside = False
                continue
            out.append(line)
    return out


def _promoted_param_lines(hlo_text: str, corpus_elems: int) -> List[str]:
    out = []
    for line in _entry_lines(hlo_text):
        m = _PARAM_RE.search(line)
        if m is None or m.group(1) != "f32":
            continue
        if _shape_bytes("f32", m.group(2)) >= corpus_elems * 4:
            out.append(line.strip())
    return out


def _int8_boundary_lines(hlo_text: str,
                         corpus_elems: int) -> Tuple[List[str], List[str]]:
    """(s8 corpus-sized entry params, widened f32/bf16 corpus-sized entry
    params). The int8-residency contract holds when the first list is
    non-empty and the second is empty: the compressed payload crossed the
    boundary at one byte per element and nobody shipped a dequantized
    copy alongside (or instead of) it."""
    s8, widened = [], []
    for line in _entry_lines(hlo_text):
        m = _PARAM_RE.search(line)
        if m is None:
            continue
        dtype = m.group(1)
        if dtype == "s8" and _shape_bytes("s8", m.group(2)) >= corpus_elems:
            s8.append(line.strip())
        elif dtype in ("f32", "bf16") and _shape_bytes(
                dtype, m.group(2)) >= corpus_elems * _DTYPE_BYTES[dtype]:
            widened.append(line.strip())
    return s8, widened


def audit_hlo_text(hlo_text: str, spec: AuditSpec,
                   label: str = "<hlo>") -> AuditReport:
    """Run every text-level contract rule; raises :class:`AuditError` on
    the first violation, returns an :class:`AuditReport` otherwise."""
    bad = _host_sync_lines(hlo_text)
    if bad:
        raise AuditError(
            "hlo-host-sync", label,
            "host callback / infeed-outfeed / custom-call sync inside a "
            "compiled step", bad)
    bad = _f64_lines(hlo_text)
    if bad:
        raise AuditError("hlo-f64", label,
                         "f64/c128 buffer in a bf16/f32 pipeline", bad)
    if spec.corpus_dtype in ("bf16", "f16") and spec.corpus_elems > 0:
        bad = _promoted_param_lines(hlo_text, spec.corpus_elems)
        if bad:
            raise AuditError(
                "hlo-corpus-promotion", label,
                f"{spec.corpus_dtype} corpus ({spec.corpus_elems} elems) "
                "enters the program as a full-size f32 parameter", bad)
    if spec.corpus_dtype == "s8" and spec.corpus_elems > 0:
        s8, widened = _int8_boundary_lines(hlo_text, spec.corpus_elems)
        if widened:
            raise AuditError(
                "hlo-int8-residency", label,
                f"quantized corpus ({spec.corpus_elems} payload elems) "
                "shipped a corpus-sized f32/bf16 entry parameter — "
                "dequantized before lowering", widened)
        if not s8:
            raise AuditError(
                "hlo-int8-residency", label,
                f"quantized corpus ({spec.corpus_elems} payload elems) "
                "has no corpus-sized s8 entry parameter — the compressed "
                "payload did not cross the program boundary at int8")
    lines = collective_lines(hlo_text)
    total = sum(b for _, b, _ in lines)
    if spec.collective_budget is not None and total > spec.collective_budget:
        raise AuditError(
            "hlo-collective-budget", label,
            f"collective traffic {total} B exceeds the budget "
            f"{spec.collective_budget} B",
            [ln for _, _, ln in lines])
    per_kind: Dict[str, int] = defaultdict(int)
    for kind, b, _ in lines:
        per_kind[kind] += b
    return AuditReport(label=label, collective_total=total,
                       collective=dict(per_kind))


def audit_executable(compiled, spec: AuditSpec = AuditSpec(),
                     label: str = "<executable>") -> AuditReport:
    """Text rules plus the peak-buffer bound on a compiled executable."""
    report = audit_hlo_text(compiled.as_text(), spec, label)
    try:
        report.peak_bytes = peak_buffer_bytes(compiled)
    except Exception:
        report.peak_bytes = None     # backend without memory_analysis
    if (spec.peak_bytes is not None and report.peak_bytes is not None
            and report.peak_bytes > spec.peak_bytes):
        raise AuditError(
            "hlo-peak-buffer", label,
            f"peak temp buffers {report.peak_bytes:.0f} B exceed the "
            f"declared bound {spec.peak_bytes} B")
    return report
