"""Runtime thread-access sanitizer: the dynamic twin of the static
lockset pass (:mod:`repro.analysis.locks`).

:class:`ThreadAccessRecorder` instruments a live object (the serving
engine) by swapping in a dynamically-built subclass whose
``__getattribute__``/``__setattr__`` record which THREADS touch which
instance attributes. After a run — the chaos soak is the intended
driver — ``violations()`` returns every attribute that was written and
touched by >= 2 threads without a declared guard: exactly the static
pass's failure condition, but measured instead of derived.

Debug-only: the instrumentation costs a dict update per attribute access
and is installed/removed explicitly (or via ``with``)::

    with ThreadAccessRecorder(engine, declared=set(GUARDED_BY)) as rec:
        ... serve traffic ...
    assert rec.violations() == []
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Set


class ThreadAccessRecorder:
    def __init__(self, obj, *, declared: Iterable[str] = ()):
        self._obj = obj
        self._orig_cls = type(obj)
        self._declared = set(declared)
        self._lock = threading.Lock()
        self.reads: Dict[str, Set[str]] = {}
        self.writes: Dict[str, Set[str]] = {}
        rec = self

        class _Instrumented(self._orig_cls):  # type: ignore[misc]
            def __getattribute__(s, name):
                if name in object.__getattribute__(s, "__dict__"):
                    rec._note(rec.reads, name)
                return object.__getattribute__(s, name)

            def __setattr__(s, name, value):
                rec._note(rec.writes, name)
                object.__setattr__(s, name, value)

        _Instrumented.__name__ = f"Recorded{self._orig_cls.__name__}"
        self._instr_cls = _Instrumented

    def _note(self, table: Dict[str, Set[str]], name: str) -> None:
        thread = threading.current_thread().name
        with self._lock:
            table.setdefault(name, set()).add(thread)

    def install(self) -> "ThreadAccessRecorder":
        self._obj.__class__ = self._instr_cls
        return self

    def uninstall(self) -> None:
        self._obj.__class__ = self._orig_cls

    __enter__ = install

    def __exit__(self, exc_type, exc, tb) -> None:
        self.uninstall()

    def shared(self) -> Dict[str, Dict[str, Set[str]]]:
        """attr -> {"read": threads, "write": threads} for every attr
        touched by >= 2 distinct threads."""
        with self._lock:
            out = {}
            for attr in set(self.reads) | set(self.writes):
                threads = (self.reads.get(attr, set())
                           | self.writes.get(attr, set()))
                if len(threads) >= 2:
                    out[attr] = {
                        "read": set(self.reads.get(attr, set())),
                        "write": set(self.writes.get(attr, set()))}
            return out

    def violations(self) -> List[str]:
        """Attributes written and touched by >= 2 threads that are not in
        the declared guard set — the measured analogue of the static
        lockset rule. (Attributes whose only writes predate install —
        init-time state — never show a writer thread and pass.)"""
        out = []
        for attr, acc in sorted(self.shared().items()):
            if attr in self._declared or not acc["write"]:
                continue
            out.append(f"{attr}: written by {sorted(acc['write'])}, "
                       f"read by {sorted(acc['read'])}, no declared guard")
        return out
