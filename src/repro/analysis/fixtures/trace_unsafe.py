"""Trigger fixture for the trace-safety rules (never executed; the lint
works on the AST). Expected violations, in order: prng-aliasing,
mutable-default, traced-truthiness, traced-cast (x2),
host-sync-in-trace, time-in-trace."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def aliased_key(seed: int):
    return jax.random.key(seed + 7)                    # prng-aliasing


def mutable_default(xs=[]):                            # mutable-default
    return xs


def round_loop(x):
    def cond(state):
        if jnp.any(state > 0):                         # traced-truthiness
            return True
        return False

    def body(state):
        v = float(jnp.sum(state))                      # traced-cast
        w = state.max().item()                         # traced-cast
        host = np.asarray(state)                       # host-sync-in-trace
        t = time.time()                                # time-in-trace
        return state - v - w - host.mean() - t

    return jax.lax.while_loop(cond, body, x)
