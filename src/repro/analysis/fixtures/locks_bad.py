"""Trigger fixture for the lockset pass: ``_count`` is written by both
the caller and worker groups with no GUARDED_BY entry and no lock;
``_state`` escapes its declared guard in ``worker_loop``."""
import threading

THREAD_ENTRY_POINTS = {
    "caller": ("submit",),
    "worker": ("worker_loop",),
}
GUARDED_BY = {
    "_state": "_lock",
}


class Racy:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0
        self._state = "idle"

    def submit(self, item):
        self._count += 1                               # lockset (shared)
        with self._lock:
            self._state = "queued"

    def worker_loop(self):
        self._count -= 1                               # lockset (shared)
        self._state = "serving"                        # lockset (guard escape)
