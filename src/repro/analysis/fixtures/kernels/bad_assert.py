"""Trigger fixture for kernel-assert: a bare assert in a kernels/
directory (stripped under ``python -O``; kernels must raise ValueError
at the host entry point instead)."""


def launch(n: int, bn: int):
    assert n % bn == 0, (n, bn)                        # kernel-assert
    return n // bn
