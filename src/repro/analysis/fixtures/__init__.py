"""Lint fixtures: each module DELIBERATELY violates one or more rules so
tests (and the CI gate's self-check) can assert the linter fires. The
default lint walk excludes any ``fixtures`` directory — lint these with
``--include-fixtures`` or by passing a file path explicitly."""
