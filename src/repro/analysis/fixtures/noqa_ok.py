"""Suppression fixture: the one violation here carries a
``# repro: noqa-<rule>`` marker, so the lint reports it as suppressed
(not active) — the mechanism tests pin."""
import jax


def suppressed_key(seed: int):
    return jax.random.key(seed + 1)  # repro: noqa-prng-aliasing
