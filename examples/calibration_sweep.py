"""Calibrate alpha_ef (paper Sec. 4.4): sweep the relaxation parameter and
print the quality-coverage frontier so a deployment can pick its operating
point.

  PYTHONPATH=src python examples/calibration_sweep.py
"""
from benchmarks.common import bench_dataset, frontier_bandit


def main():
    ds = bench_dataset(256, 8)
    print("alpha_ef   coverage   overlap@5   flops_saving")
    for p in frontier_bandit(ds, k=5,
                             alphas=(0.05, 0.1, 0.2, 0.4, 0.8, 1.6)):
        print(f"{p['alpha_ef']:8.2f} {100*p['coverage']:9.1f}% "
              f"{p['overlap']:10.3f} {p['flops_saving']:11.1f}x")
    print("\npick the smallest alpha whose overlap meets your SLO; "
          "larger alpha = more conservative (more compute, higher fidelity).")


if __name__ == "__main__":
    main()
