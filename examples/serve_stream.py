"""Streaming serving driver: a Poisson query stream through RetrievalEngine.

Arrivals are simulated on a virtual clock (deterministic queue waits and
deadline misses, independent of host speed); batch execution still runs for
real, so the printed reveal fractions and flavors are genuine. Mixed query
lengths exercise the shape buckets — after ``warmup()`` the whole stream
serves with zero recompiles.

  PYTHONPATH=src python examples/serve_stream.py [--n-requests 64] [--rate 200]
"""
import argparse
import time

import numpy as np

from repro.data.synthetic import make_retrieval_dataset
from repro.serve import EngineConfig, Request, RetrievalEngine


class SimClock:
    """Manually-advanced clock for deterministic arrival simulation."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=256)
    ap.add_argument("--n-requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean arrival rate (requests / simulated second)")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=20.0,
                    help="per-request completion deadline")
    ap.add_argument("--flavor", default="auto",
                    choices=("auto", "dense", "bandit"))
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    print(f"building corpus: {args.n_docs} docs ...")
    ds = make_retrieval_dataset(n_docs=args.n_docs, n_queries=args.n_requests,
                                doc_len=48, min_doc_len=12, query_len=32,
                                dim=64, seed=args.seed)

    clock = SimClock()
    cfg = EngineConfig(batch_size=args.batch_size,
                       deadline_s=args.deadline_ms / 1e3,
                       token_buckets=(8, 16, 32), cand_buckets=(32, 64),
                       max_k=10, flavor=args.flavor, bandit_min_candidates=64,
                       alpha_ef=args.alpha, stage1_candidates=32,
                       seed=args.seed)
    engine = RetrievalEngine(ds.doc_embs, ds.doc_mask, cfg, clock=clock)

    t0 = time.time()
    buckets = engine.warmup()
    print(f"warmup compiled {len(buckets)} bucket programs "
          f"in {time.time() - t0:.1f}s:")
    for key in buckets:
        print(f"  {key}")

    # Poisson arrivals, mixed query lengths, mixed candidate provenance:
    # half the requests bring their own stage-1 list, half use the engine's.
    gaps = rng.exponential(1.0 / args.rate, args.n_requests)
    arrivals = np.cumsum(gaps)
    done = []
    t0 = time.time()
    for i in range(args.n_requests):
        # serve any admission deadline that expires before the next arrival
        while True:
            exp = engine.next_expiry()
            if exp is None or exp > arrivals[i]:
                break
            clock.t = exp
            done += engine.poll()
        clock.t = float(arrivals[i])
        n_tok = int(rng.integers(4, 33))
        cand = (rng.choice(args.n_docs, 48, replace=False)
                if rng.random() < 0.5 else None)
        engine.submit(Request(query=ds.queries[i][:n_tok], k=10,
                              deadline_s=args.deadline_ms / 1e3,
                              cand_ids=cand))
        done += engine.poll()
    clock.t = float(arrivals[-1]) + cfg.deadline_s + 1e-6
    done += engine.drain()
    wall = time.time() - t0

    for c in done[:8]:
        print(f"  rid={c.rid:3d} flavor={c.flavor:6s} bucket={c.bucket} "
              f"wait={1e3 * c.queue_wait_s:6.2f}ms "
              f"reveal={100 * c.reveal_fraction:5.1f}% "
              f"miss={c.deadline_miss} top1={int(c.topk_ids[0])}")
    if len(done) > 8:
        print(f"  ... ({len(done) - 8} more)")

    s = engine.metrics.summary()
    print(f"\nserved {s['n_requests']} requests in {s['n_batches']} batches "
          f"({wall:.2f}s wall):")
    print(f"  queue wait p50/p99 (simulated): "
          f"{s['queue_wait_p50_ms']:.2f} / {s['queue_wait_p99_ms']:.2f} ms")
    print(f"  deadline miss rate: {100 * s['deadline_miss_rate']:.1f}%")
    print(f"  mean batch occupancy: {100 * s['mean_occupancy']:.1f}%")
    print(f"  mean reveal fraction: {100 * s['mean_reveal_fraction']:.1f}%")
    print(f"  compiles after warmup: {s['compiles_after_warmup']}")


if __name__ == "__main__":
    main()
