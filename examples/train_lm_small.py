"""Train a small (~25M param) LM with the full production stack: microbatch
gradient accumulation, AdamW + cosine schedule, async checkpointing and
crash-safe resume. A second invocation resumes from the latest checkpoint.

  PYTHONPATH=src python examples/train_lm_small.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models.transformer import init_lm
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_step import TrainState, make_lm_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args(argv)

    cfg = LMConfig(name="lm-25m", n_layers=6, d_model=384, n_heads=6,
                   n_kv_heads=2, d_head=64, d_ff=1024, vocab=8192)
    print(f"params: {cfg.param_count()/1e6:.1f}M")

    params = init_lm(jax.random.key(0), cfg)
    opt = adamw(cosine_schedule(3e-4, 20, args.steps))
    state = TrainState(params=params, opt=opt.init(params))
    step = jax.jit(make_lm_train_step(cfg, opt, num_microbatches=2))

    def batch_fn(i):
        key = jax.random.fold_in(jax.random.key(42), i)
        toks = jax.random.randint(key, (8, 128), 0, cfg.vocab)
        return {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}

    trainer = Trainer(step, batch_fn, state,
                      TrainerConfig(total_steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=20))
    trainer.maybe_restore()
    trainer.run()
    print("done; metrics tail:", trainer.metrics_log[-2:])


if __name__ == "__main__":
    main()
