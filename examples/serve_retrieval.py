"""End-to-end serving driver: build an index, then serve BATCHED queries
through the two-stage pipeline with exact vs Col-Bandit reranking.

  PYTHONPATH=src python examples/serve_retrieval.py [--n-docs 512]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.base import BanditConfig
from repro.data.synthetic import make_retrieval_dataset
from repro.retrieval.index import build_index
from repro.retrieval.pipeline import rerank_query


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=512)
    ap.add_argument("--n-queries", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=0.3)
    args = ap.parse_args(argv)

    print(f"building index: {args.n_docs} docs ...")
    ds = make_retrieval_dataset(n_docs=args.n_docs, n_queries=args.n_queries,
                                seed=1)
    index = build_index(ds.doc_embs, ds.doc_mask, ds.doc_lens)

    stats = {"exact": [], "bandit": []}
    t0 = time.time()
    for qi in range(ds.n_queries):
        q = jnp.asarray(ds.queries[qi])
        e = rerank_query(index, q, method="exact", k=5,
                         qrels_row=ds.qrels[qi])
        b = rerank_query(index, q, method="bandit", k=5,
                         bandit=BanditConfig(k=5, alpha_ef=args.alpha),
                         qrels_row=ds.qrels[qi], seed=qi)
        stats["exact"].append(e)
        stats["bandit"].append(b)
        print(f"  q{qi:02d}: overlap={b.overlap:.2f} "
              f"coverage={100*b.coverage:4.1f}% "
              f"saving={e.flops/max(b.flops,1):4.1f}x "
              f"recall@5={b.metrics['recall']:.2f} "
              f"(exact recall {e.metrics['recall']:.2f})")

    cov = np.mean([r.coverage for r in stats["bandit"]])
    sav = np.mean([e.flops / max(b.flops, 1)
                   for e, b in zip(stats["exact"], stats["bandit"])])
    ov = np.mean([r.overlap for r in stats["bandit"]])
    print(f"\nserved {ds.n_queries} queries in {time.time()-t0:.1f}s: "
          f"mean coverage {100*cov:.1f}%, mean saving {sav:.1f}x, "
          f"mean overlap@5 {ov:.2f}")


if __name__ == "__main__":
    main()
