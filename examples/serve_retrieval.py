"""End-to-end serving driver: build an index, then serve BATCHED queries
through the unified two-stage pipeline (``serve_queries``) with exact vs
Col-Bandit reranking — the same engine-facing rerank steps
``repro.serve.RetrievalEngine`` AOT-compiles.

  PYTHONPATH=src python examples/serve_retrieval.py [--n-docs 512]
"""
import argparse
import time

import numpy as np

from repro.configs.base import BanditConfig
from repro.data.synthetic import make_retrieval_dataset
from repro.retrieval.index import build_index
from repro.retrieval.pipeline import serve_queries


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=512)
    ap.add_argument("--n-queries", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=0.3)
    args = ap.parse_args(argv)

    print(f"building index: {args.n_docs} docs ...")
    ds = make_retrieval_dataset(n_docs=args.n_docs, n_queries=args.n_queries,
                                seed=1)
    index = build_index(ds.doc_embs, ds.doc_mask, ds.doc_lens)
    queries = np.asarray(ds.queries)                       # (B, T, M)

    t0 = time.time()
    dense = serve_queries(index, queries, k=5, flavor="dense")
    bandit = serve_queries(index, queries, k=5, flavor="bandit",
                           bandit=BanditConfig(k=5, alpha_ef=args.alpha))
    dt = time.time() - t0

    overlaps = []
    for qi in range(ds.n_queries):
        ov = len(set(dense.topk_ids[qi]) & set(bandit.topk_ids[qi])) / 5.0
        overlaps.append(ov)
        rel = set(np.nonzero(ds.qrels[qi])[0])
        rec = len(rel & set(int(d) for d in bandit.topk_ids[qi]
                            if d >= 0)) / max(len(rel), 1)
        print(f"  q{qi:02d}: overlap={ov:.2f} "
              f"coverage={100 * bandit.reveal_fraction[qi]:4.1f}% "
              f"recall@5={rec:.2f}")

    print(f"\nserved {ds.n_queries} queries in {dt:.1f}s: "
          f"mean coverage {100 * bandit.reveal_fraction.mean():.1f}%, "
          f"mean overlap@5 {np.mean(overlaps):.2f}, "
          f"frontier occupancy {bandit.stats[0]:.2f}")


if __name__ == "__main__":
    main()