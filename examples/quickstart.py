"""Quickstart: Col-Bandit reranking on a synthetic corpus in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs.base import BanditConfig
from repro.data.synthetic import make_retrieval_dataset
from repro.retrieval.index import build_index
from repro.retrieval.pipeline import rerank_query


def main():
    ds = make_retrieval_dataset(n_docs=256, n_queries=4, seed=0)
    index = build_index(ds.doc_embs, ds.doc_mask, ds.doc_lens)
    query = jnp.asarray(ds.queries[0])

    exact = rerank_query(index, query, method="exact", k=5)
    bandit = rerank_query(index, query, method="bandit", k=5,
                          bandit=BanditConfig(k=5, alpha_ef=0.3),
                          qrels_row=ds.qrels[0])

    print(f"exact top-5 docs : {exact.topk_docs}")
    print(f"bandit top-5 docs: {bandit.topk_docs}")
    print(f"overlap@5        : {bandit.overlap:.2f}")
    print(f"coverage         : {100 * bandit.coverage:.1f}% "
          f"of the MaxSim matrix")
    print(f"MaxSim FLOPs     : {bandit.flops:.3g} vs {bandit.flops_exact:.3g} "
          f"({bandit.flops_exact / max(bandit.flops, 1):.1f}x saving)")
    print(f"task metrics     : {bandit.metrics}")


if __name__ == "__main__":
    main()
