"""Quickstart: Col-Bandit reranking on a synthetic corpus in ~30 lines.

Runs through the unified batched pipeline entrypoint
(``repro.retrieval.pipeline.serve_queries``) — the exact stage-1 +
rerank code path the serving engine AOT-compiles.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import BanditConfig
from repro.data.synthetic import make_retrieval_dataset
from repro.retrieval.index import build_index
from repro.retrieval.pipeline import serve_queries


def main():
    ds = make_retrieval_dataset(n_docs=256, n_queries=4, seed=0)
    index = build_index(ds.doc_embs, ds.doc_mask, ds.doc_lens)
    queries = np.asarray(ds.queries)                       # (B, T, M)

    dense = serve_queries(index, queries, k=5, flavor="dense")
    bandit = serve_queries(index, queries, k=5, flavor="bandit",
                           bandit=BanditConfig(k=5, alpha_ef=0.3))

    overlap = np.mean([len(set(d) & set(b)) / 5.0
                       for d, b in zip(dense.topk_ids, bandit.topk_ids)])
    print(f"dense top-5 (q0) : {dense.topk_ids[0]}")
    print(f"bandit top-5 (q0): {bandit.topk_ids[0]}")
    print(f"mean overlap@5   : {overlap:.2f}")
    print(f"reveal fraction  : {100 * bandit.reveal_fraction.mean():.1f}% "
          f"of the MaxSim matrix (dense computes 100%)")
    print(f"frontier stats   : occupancy={bandit.stats[0]:.2f} "
          f"rounds={bandit.stats[1]:.0f} "
          f"lockstep_waste={bandit.stats[2]:.0f}")


if __name__ == "__main__":
    main()
